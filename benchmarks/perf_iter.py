"""§Perf iteration helper: measure (peak, roofline terms) for one
(arch × cell) under config/exec/rule overrides — the hypothesis→change→
measure loop's instrument.

    PYTHONPATH=src python -m benchmarks.perf_iter --arch kimi-k2-1t-a32b \
        --cell train_4k --micro 2 --attention chunked --chunk 1024
"""

import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
import time


def measure(arch: str, cell_name: str, *, micro=None, remat=None,
            attention=None, chunk=None, fsdp=None, seq_shard=False,
            multi_pod=False, cache_seq_shard=None) -> dict:
    import jax  # noqa: F401  (device count must be set before init)

    import repro.configs as C
    from repro.launch.build import build_cell, rules_for
    from repro.launch.hlo_analysis import analyze_hlo
    from repro.launch.mesh import make_production_mesh

    spec = C.get(arch)
    model_kw = {}
    if remat is not None:
        model_kw["remat_policy"] = remat
    if attention is not None:
        model_kw["attention_impl"] = attention
    if chunk is not None:
        model_kw["attention_chunk"] = chunk
    if model_kw:
        spec = spec.replace_model(**model_kw)
    ex = spec.exec
    if micro is not None:
        ex = ex.replace(num_microbatches=micro)
    if remat is not None:
        ex = ex.replace(remat=remat)
    if fsdp is not None:
        ex = ex.replace(fsdp=fsdp)
    spec = dataclasses.replace(spec, exec=ex)

    cell = C.CELLS[cell_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    overrides = {}
    if seq_shard:
        overrides["seq"] = "model"
    if cache_seq_shard:
        overrides["cache_seq"] = cache_seq_shard
    rules = rules_for(spec, cell, mesh, overrides=overrides or None)

    t0 = time.time()
    built = build_cell(spec, cell, mesh, rules=rules, exec_override=ex)
    compiled = built.lower(mesh).compile()
    ma = compiled.memory_analysis()
    peak = (ma.argument_size_in_bytes + ma.output_size_in_bytes
            - ma.alias_size_in_bytes + ma.temp_size_in_bytes)
    cost = analyze_hlo(compiled.as_text())
    terms = {
        "compute_s": cost.flops / 197e12,
        "memory_s": cost.hbm_bytes / 819e9,
        "collective_s": cost.collective_bytes / 50e9,
    }
    out = {
        "arch": arch, "cell": cell_name,
        "variant": {"micro": micro, "remat": remat, "attention": attention,
                    "chunk": chunk, "fsdp": fsdp, "seq_shard": seq_shard,
                    "cache_seq_shard": cache_seq_shard,
                    "multi_pod": multi_pod},
        "peak_gib": peak / 2**30,
        **{k: round(v, 3) for k, v in terms.items()},
        "step_s": round(max(terms.values()), 3),
        "dominant": max(terms, key=terms.get),
        "collective_breakdown_gb": {
            k: round(v / 1e9, 1) for k, v in cost.collective_breakdown.items()
        },
        "compile_s": round(time.time() - t0, 1),
    }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--cell", required=True)
    ap.add_argument("--micro", type=int, default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--attention", default=None)
    ap.add_argument("--chunk", type=int, default=None)
    ap.add_argument("--fsdp", type=lambda s: s == "true", default=None)
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--cache-seq-shard", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    out = measure(args.arch, args.cell, micro=args.micro, remat=args.remat,
                  attention=args.attention, chunk=args.chunk, fsdp=args.fsdp,
                  seq_shard=args.seq_shard, multi_pod=args.multi_pod,
                  cache_seq_shard=args.cache_seq_shard)
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
