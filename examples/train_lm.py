"""End-to-end training example: a ~100M-parameter granite-family LM trained
for a few hundred steps with the full production stack — synthetic data
pipeline, AdamW + warmup-cosine, microbatched gradient accumulation, async
checkpointing, preemption handling and the straggler monitor.

    PYTHONPATH=src python examples/train_lm.py --preset 20m  --steps 200
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

The 20m preset finishes in minutes on this CPU container; the 100m preset
is the assignment's "~100M for a few hundred steps" driver (CPU wall time
is substantial; on one real accelerator it is minutes).  Training resumes
from the newest checkpoint automatically — Ctrl-C and re-run to see the
restart path.
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.checkpoint import CheckpointManager
from repro.configs.base import ExecConfig
from repro.data import SyntheticDataset, shard_batch
from repro.models import Model, ModelConfig, count_params
from repro.runtime.loop import PreemptionGuard, TrainLoop
from repro.runtime.steps import init_train_state, make_train_step

PRESETS = {
    # ~19M params: d=384, L=6 — quick on CPU
    "20m": dict(num_layers=6, d_model=384, num_heads=6, num_kv_heads=2,
                d_ff=1536, vocab_size=8192, head_dim=64),
    # ~105M params: d=768, L=12 — the assignment's ~100M driver
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
                 d_ff=3072, vocab_size=32768, head_dim=64),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=list(PRESETS), default="20m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="train_lm_ckpt")
    args = ap.parse_args()

    cfg = ModelConfig(
        name=f"granite-{args.preset}", family="dense",
        param_dtype="float32", compute_dtype="bfloat16",
        remat_policy="none", **PRESETS[args.preset],
    )
    model = Model(cfg)
    n = count_params(model.param_specs())
    print(f"[train_lm] {cfg.name}: {n/1e6:.1f}M params, "
          f"{args.global_batch}×{args.seq_len} tokens/step")

    ex = ExecConfig(
        learning_rate=args.lr, warmup_steps=20, total_steps=args.steps,
        num_microbatches=args.microbatches, remat="none",
    )
    state = init_train_state(model, ex, jax.random.key(0))
    step = jax.jit(make_train_step(model, ex), donate_argnums=(0,))
    ds = SyntheticDataset(cfg, args.global_batch, args.seq_len, seed=0)

    loop = TrainLoop(
        train_step=step, batch_at=ds.batch_at, place_batch=shard_batch,
        state=state,
        checkpoints=CheckpointManager(args.ckpt_dir, keep_n=3),
        checkpoint_every=50, log_every=10,
        guard=PreemptionGuard(install=True),
    )
    loop.maybe_restore()
    result = loop.run(args.steps)
    hist = result["history"]
    if hist:
        print(f"[train_lm] loss {hist[0]['loss']:.3f} → {hist[-1]['loss']:.3f} "
              f"over {result['final_step']} steps "
              f"({result['exit']}, {len(result['stragglers'])} stragglers)")


if __name__ == "__main__":
    main()
