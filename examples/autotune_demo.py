"""The paper's algorithm end to end, twice:

  A. On its OWN domain — the emulated Scout cluster evaluation: profile a
     job's memory on "one machine", split the 69-config search space,
     Bayesian-optimize, and compare against CherryPick across seeds.
  B. Beyond the paper — the SAME algorithm tuning TPU execution
     configurations (microbatch × remat × FSDP × sequence-sharding) for an
     assigned architecture on the production (16,16) mesh, where a trial is
     an AOT compile + roofline estimate.  (Pass --tpu; each trial compiles
     for ~10–20 s on this CPU container.)

    PYTHONPATH=src python examples/autotune_demo.py
    PYTHONPATH=src python examples/autotune_demo.py --tpu --budget 8
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def demo_cluster(seeds: int = 15) -> None:
    """Each job's ``seeds`` repetitions run through ONE streaming
    `TuningSession` per job class — every replica of both searchers advances
    in device-resident lockstep, trace-identical to looping the sequential
    engine, minus thousands of per-step host round-trips."""
    from repro.core.profiler import profile_job
    from repro.fleet import TuningSession, cluster_fleet

    print("=== A. Ruya on the paper's own domain (3 job classes) ===")
    for key in ["kmeans/spark/huge", "terasort/hadoop/bigdata",
                "logregr/spark/huge"]:
        job = cluster_fleet([key])[0]
        # Profile once; the paper only re-profiles when the context changes.
        job.profile_result = profile_job(job.profile_run, job.full_input_size)
        # Warm-starting stays off: this demo compares COLD searches across
        # seeds (the paper's repetition protocol), so replicas must not
        # seed each other.
        session = TuningSession(to_exhaustion=True, warm_start=False)
        ruya = [session.submit(job, seed=s) for s in range(seeds)]
        cp = [
            session.submit(job, seed=s, mode="cherrypick")
            for s in range(seeds)
        ]
        session.drain()
        ruya_iters = [h.outcome().iterations_until(1.0) for h in ruya]
        cp_iters = [h.outcome().iterations_until(1.0) for h in cp]
        category = job.profile_result.model.category.value
        print(f"  {key:28s} [{category:7s}] "
              f"iterations-to-optimal: Ruya {np.mean(ruya_iters):5.1f} "
              f"vs CherryPick {np.mean(cp_iters):5.1f}")


def demo_tpu(arch: str, cell: str, budget: int) -> None:
    print(f"\n=== B. Ruya tuning TPU exec configs for {arch} × {cell} ===")
    from repro.launch.autotune import run_autotune

    run_autotune(arch, cell, budget=budget,
                 cache_path="artifacts/autotune/demo_cache.json")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tpu", action="store_true",
                    help="also run the TPU exec-config tuner (compiles!)")
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--cell", default="train_4k")
    ap.add_argument("--budget", type=int, default=8)
    args = ap.parse_args()
    demo_cluster()
    if args.tpu:
        demo_tpu(args.arch, args.cell, args.budget)
    else:
        print("\n(pass --tpu to run the beyond-paper TPU exec-config tuner)")
