"""The paper's algorithm end to end, twice:

  A. On its OWN domain — the emulated Scout cluster evaluation: profile a
     job's memory on "one machine", split the 69-config search space,
     Bayesian-optimize, and compare against CherryPick across seeds.
  B. Beyond the paper — the SAME algorithm tuning TPU execution
     configurations (microbatch × remat × FSDP × sequence-sharding) for an
     assigned architecture on the production (16,16) mesh, where a trial is
     an AOT compile + roofline estimate.  (Pass --tpu; each trial compiles
     for ~10–20 s on this CPU container.)

    PYTHONPATH=src python examples/autotune_demo.py
    PYTHONPATH=src python examples/autotune_demo.py --tpu --budget 8
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def demo_cluster(seeds: int = 15) -> None:
    from repro.cluster import ClusterSimulator
    from repro.core import run_cherrypick, run_ruya

    GiB = 1024**3
    print("=== A. Ruya on the paper's own domain (3 job classes) ===")
    for key in ["kmeans/spark/huge", "terasort/hadoop/bigdata",
                "logregr/spark/huge"]:
        sim = ClusterSimulator.for_job(key)
        ruya_iters, cp_iters = [], []
        prof = None
        for seed in range(seeds):
            rep = run_ruya(
                profile_run=sim.profile_run_fn(),
                full_input_size=sim.job.input_gb * GiB,
                space=sim.space, cost_fn=sim.cost_fn(),
                rng=np.random.default_rng(seed),
                per_node_overhead=0.5 * GiB, to_exhaustion=True,
                profile_result=prof,
            )
            prof = rep.profile
            cp = run_cherrypick(space=sim.space, cost_fn=sim.cost_fn(),
                                rng=np.random.default_rng(seed),
                                to_exhaustion=True)
            ruya_iters.append(rep.trace.iterations_until(1.0))
            cp_iters.append(cp.iterations_until(1.0))
        print(f"  {key:28s} [{prof.model.category.value:7s}] "
              f"iterations-to-optimal: Ruya {np.mean(ruya_iters):5.1f} "
              f"vs CherryPick {np.mean(cp_iters):5.1f}")


def demo_tpu(arch: str, cell: str, budget: int) -> None:
    print(f"\n=== B. Ruya tuning TPU exec configs for {arch} × {cell} ===")
    from repro.launch.autotune import run_autotune

    run_autotune(arch, cell, budget=budget,
                 cache_path="artifacts/autotune/demo_cache.json")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tpu", action="store_true",
                    help="also run the TPU exec-config tuner (compiles!)")
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--cell", default="train_4k")
    ap.add_argument("--budget", type=int, default=8)
    args = ap.parse_args()
    demo_cluster()
    if args.tpu:
        demo_tpu(args.arch, args.cell, args.budget)
    else:
        print("\n(pass --tpu to run the beyond-paper TPU exec-config tuner)")
