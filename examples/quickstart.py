"""Quickstart: the three things this framework does, in 90 seconds on CPU.

  1. Run the PAPER's algorithm through the streaming session API
     (`repro.fleet.TuningSession`): memory-aware profiling + two-phase
     Bayesian search for the cheapest cluster configuration (vs the
     CherryPick baseline) on the emulated Scout evaluation.
  2. Train a reduced LM from the architecture zoo with the fault-tolerant
     loop (checkpoints land in ./quickstart_ckpt).
  3. Serve it: prefill + batched greedy decode.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np


def part1_ruya_search():
    print("\n=== 1. Ruya vs CherryPick on the emulated Scout cluster ===")
    from repro.fleet import TuningSession, cluster_fleet

    GiB = 1024**3
    # One streaming session serves every search style: submit jobs (they are
    # profiled and split on admission), drain, read first-class outcomes.
    # Both searches share one session — and one lockstep device chunk.
    session = TuningSession(to_exhaustion=True)
    job = cluster_fleet(["kmeans/spark/huge"])[0]
    h_ruya = session.submit(job, seed=0)                      # two-phase Ruya
    h_cp = session.submit(job, seed=0, mode="cherrypick")     # baseline
    session.drain()
    rep, cp = h_ruya.outcome(), h_cp.outcome()
    mm = rep.memory_model
    print(f"  profiled memory model: {mm.category.value}, "
          f"estimate {mm.estimate(job.full_input_size)/GiB:.0f} GB")
    print(f"  priority group: {len(rep.priority)}/69 configurations")
    print(f"  iterations to the optimal config: "
          f"Ruya {rep.iterations_until(1.0)} vs "
          f"CherryPick {cp.iterations_until(1.0)}")


def part2_train():
    print("\n=== 2. Train a reduced granite-8b with the fault-tolerant loop ===")
    import repro.configs as C
    from repro.checkpoint import CheckpointManager
    from repro.data import SyntheticDataset, shard_batch
    from repro.models import Model
    from repro.runtime.loop import TrainLoop
    from repro.runtime.steps import init_train_state, make_train_step

    spec = C.smoke("granite-8b")
    model = Model(spec.model)
    ex = spec.exec.replace(learning_rate=5e-3, warmup_steps=5, total_steps=60)
    state = init_train_state(model, ex, jax.random.key(0))
    step = jax.jit(make_train_step(model, ex), donate_argnums=(0,))
    ds = SyntheticDataset(spec.model, global_batch=8, seq_len=32)
    ckpt_dir = tempfile.mkdtemp(prefix="quickstart_ckpt_")
    loop = TrainLoop(
        train_step=step, batch_at=ds.batch_at, place_batch=shard_batch,
        state=state, checkpoints=CheckpointManager(ckpt_dir, keep_n=2),
        checkpoint_every=30, log_every=20,
        log_fn=lambda s: print("  " + s),
    )
    loop.run(60)
    print(f"  checkpoints in {ckpt_dir}: steps {loop.checkpoints.all_steps()}")
    return spec, loop.state


def part3_serve(spec, state):
    print("\n=== 3. Serve it: prefill + batched greedy decode ===")
    from repro.models import Model
    from repro.models.spec import is_spec
    from repro.runtime.decode_loop import ServeLoop
    from repro.runtime.steps import make_serve_steps

    model = Model(spec.model)
    prefill, decode = make_serve_steps(model)
    B, MAX = 2, 64

    def init_cache():
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            model.cache_specs(B, MAX), is_leaf=is_spec,
        )

    loop = ServeLoop(
        prefill_step=jax.jit(prefill),
        decode_step=jax.jit(decode, donate_argnums=(1,)),
        params=state["params"], init_cache=init_cache, eos_id=-1,
    )
    prompt = jnp.ones((B, 8), jnp.int32) * 5
    out = loop.generate({"tokens": prompt}, max_new_tokens=12,
                        echo_metrics=True)
    print(f"  generated: {out['tokens'][0].tolist()}")
    print(f"  throughput: {out['metrics']['tokens_per_s']:.0f} tok/s "
          f"(CPU, reduced config)")


if __name__ == "__main__":
    part1_ruya_search()
    spec, state = part2_train()
    part3_serve(spec, state)
    print("\nQuickstart complete.")
