"""Streaming fleet tuning: jobs arrive over time, warm starts are logged.

A `TuningSession` is a long-lived tuning service.  Jobs are submitted in
waves (here: the paper's recurring Spark/Hadoop workloads re-arriving, the
Blink scenario); each submission is probe-classified against the session's
`ProfileCache`, its §III-D split is computed on device, and the search
joins a lockstep chunk at the next `step()`.  Once a memory-signature
class has completed trials, later arrivals in the same class are
WARM-STARTED: their packed observation/feature buffers are seeded from the
class history, the random initialization is skipped, and the EI stop
criterion usually fires after a handful of fresh trials.

    PYTHONPATH=src python examples/streaming_fleet.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.bayesopt import BOSettings
from repro.fleet import ProfileCache, TuningSession, cluster_fleet

KEYS = ["terasort/hadoop/bigdata", "kmeans/spark/huge",
        "join/spark/bigdata", "pagerank/hadoop/bigdata"]
WAVES = 3


def main() -> None:
    session = TuningSession(
        settings=BOSettings(max_iters=16),
        cache=ProfileCache(),  # session-owned profile reuse (Flora-style)
        warm_start=True,
        to_exhaustion=False,  # stop at the EI convergence threshold
    )
    reported = 0
    for wave in range(WAVES):
        print(f"\n== wave {wave}: {len(KEYS)} jobs arrive ==")
        for i, job in enumerate(cluster_fleet(KEYS)):
            session.submit(job, seed=100 * wave + i)
        # Advance the whole fleet one batched BO iteration at a time; a real
        # service would interleave these steps with further submissions.
        while session.step():
            pass
        for out in session.results()[reported:]:
            tag = f"warm×{len(out.seeded)}" if out.seeded else "cold"
            print(f"  {out.name:26s} [{out.memory_model.category.value:7s}]"
                  f" {tag:8s} fresh trials {len(out.records):2d} "
                  f"best {out.best_cost:.3f}")
        reported = len(session.results())

    outs = session.results()
    warm = [o for o in outs if o.seeded]
    cold = [o for o in outs if not o.seeded]
    mean = lambda xs: sum(xs) / max(len(xs), 1)
    print(f"\nprofile cache: {session.cache.hits} hits / "
          f"{session.cache.misses} misses; "
          f"warm-started {session.warm_hits} jobs "
          f"({session.warm_trials} seeded trials)")
    print(f"fresh trials to convergence: "
          f"cold {mean([len(o.records) for o in cold]):.1f} "
          f"vs warm {mean([len(o.records) for o in warm]):.1f}")


if __name__ == "__main__":
    main()
